//! Chaos tests of the fault-tolerance layer over real loopback
//! sockets: worker panics injected at runtime (no client request may
//! hang — every one completes with oracle-bit-identical logits or a
//! typed error), restart-budget exhaustion marking a model unhealthy,
//! exact deadline-shed accounting, v1 (pre-deadline) frames served by
//! a v2 server, and byte-level connection chaos (malformed frames,
//! mid-frame drops, slow writers) that must never wedge the server.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use scnn::coordinator::batcher::{is_deadline_error, is_worker_panic_error};
use scnn::coordinator::chaos::{
    chaos_factory, drop_after, malformed_frame, slow_writer, ChaosSwitch,
};
use scnn::coordinator::net::MAGIC;
use scnn::coordinator::{
    is_shed_error, is_timeout_error, BatchPolicy, Coordinator, ExecutorSpec, Frame, FrameReader,
    ModelRegistry, NetClient, NetServer, OverloadPolicy, PoolConfig, Status, SyntheticExecutor,
    TenantPolicy,
};

const SPEC: ExecutorSpec = ExecutorSpec { image_len: 12, batch: 4, classes: 5 };

/// A deterministic fake "image" for request index `i`.
fn image(i: usize) -> Vec<f32> {
    (0..SPEC.image_len).map(|p| ((i * 31 + p * 7) % 17) as f32 * 0.125 - 1.0).collect()
}

/// Registry + server over a chaos-wrapped synthetic pool; returns the
/// switch so tests can dial the panic rate while traffic flows.
fn serve_chaos(
    workers: usize,
    latency: Duration,
    restart_budget: usize,
) -> (Arc<ChaosSwitch>, Arc<ModelRegistry>, NetServer) {
    let switch = ChaosSwitch::new(0.0);
    let factory = chaos_factory(SyntheticExecutor::factory(SPEC, latency), switch.clone(), 0xC4A0);
    let coord = Coordinator::start_with(
        factory,
        PoolConfig { workers, restart_budget, ..PoolConfig::default() },
    )
    .expect("start chaos pool");
    let registry = Arc::new(ModelRegistry::new(TenantPolicy::default()));
    assert!(registry.register("toy", coord).is_none());
    let server = NetServer::bind("127.0.0.1:0", registry.clone()).expect("bind loopback");
    (switch, registry, server)
}

/// The headline acceptance test: with worker panics injected at
/// runtime, no request ever hangs — each completes with logits
/// bit-identical to the in-process oracle or a typed error — and once
/// injection stops the pool respawns back to full, correct service.
#[test]
fn injected_panics_never_hang_clients_and_pool_recovers() {
    let (switch, registry, server) = serve_chaos(2, Duration::from_millis(1), 10_000);
    let addr = server.local_addr();
    let oracle = SyntheticExecutor::new(SPEC);
    switch.set_rate(0.3);
    let clients = 4usize;
    let per_client = 24usize;
    let mut handles = Vec::new();
    for t in 0..clients {
        handles.push(std::thread::spawn(move || -> (usize, usize) {
            let mut client = NetClient::connect(addr)
                .expect("connect")
                .with_deadline(Some(Duration::from_secs(5)))
                .with_retries(0);
            let oracle = SyntheticExecutor::new(SPEC);
            let (mut ok, mut typed) = (0usize, 0usize);
            for i in 0..per_client {
                let idx = t * per_client + i;
                match client.infer("toy", &image(idx)) {
                    Ok(logits) => {
                        assert_eq!(logits, oracle.reference_logits(&image(idx)), "request {idx}");
                        ok += 1;
                    }
                    Err(e) => {
                        assert!(
                            is_worker_panic_error(&e)
                                || is_shed_error(&e)
                                || is_deadline_error(&e)
                                || is_timeout_error(&e),
                            "request {idx}: error must be typed, got: {e:#}"
                        );
                        typed += 1;
                    }
                }
            }
            (ok, typed)
        }));
    }
    let (mut ok, mut typed) = (0usize, 0usize);
    for h in handles {
        let (o, e) = h.join().expect("client thread must complete — no hangs");
        ok += o;
        typed += e;
    }
    assert_eq!(ok + typed, clients * per_client, "every request accounted for");
    assert!(typed > 0, "a 30% panic rate over {} requests must fail some", clients * per_client);
    switch.off();
    // Recovery: the pool respawned through every injected panic, so it
    // must come back healthy and bit-exact at full worker count.
    let entry = registry.get("toy").expect("model registered");
    let deadline = Instant::now() + Duration::from_secs(10);
    while !entry.healthy() {
        assert!(Instant::now() < deadline, "pool never recovered after injection stopped");
        std::thread::sleep(Duration::from_millis(10));
    }
    let mut client = NetClient::connect(addr).expect("reconnect");
    for i in 0..16 {
        let x = image(1000 + i);
        assert_eq!(client.infer("toy", &x).expect("post-chaos infer"), oracle.reference_logits(&x));
    }
    server.shutdown();
    let (_, m) = registry.shutdown_all().remove(0);
    assert!(m.worker_panics > 0, "panics were injected: {m:?}");
    assert!(m.worker_respawns > 0, "workers must have respawned: {m:?}");
    assert_eq!(m.worker_panics, m.worker_respawns, "budget 10k: every panic respawns");
}

/// A worker that exhausts its restart budget stays down: the model
/// reports unhealthy in the registry, and requests keep failing
/// typed — never hanging.
#[test]
fn restart_budget_exhaustion_marks_model_unhealthy() {
    let (switch, registry, server) = serve_chaos(1, Duration::ZERO, 0);
    switch.set_rate(1.0);
    let entry = registry.get("toy").expect("model registered");
    assert!(entry.healthy(), "healthy before any panic");
    // First request crashes the only worker; budget 0 forbids respawn.
    let err = entry
        .infer_within(image(0), Some(Duration::from_secs(5)))
        .expect_err("rate-1.0 panic must fail the request");
    assert!(is_worker_panic_error(&err), "typed worker-panic error, got: {err:#}");
    let deadline = Instant::now() + Duration::from_secs(10);
    while entry.healthy() {
        assert!(Instant::now() < deadline, "exhausted pool must turn unhealthy");
        std::thread::sleep(Duration::from_millis(5));
    }
    // The dead shard answers immediately with a typed error — no hang.
    let err = entry
        .infer_within(image(1), Some(Duration::from_secs(5)))
        .expect_err("dead pool must reject");
    let msg = format!("{err:#}");
    assert!(!msg.is_empty());
    server.shutdown();
    let (_, m) = registry.shutdown_all().remove(0);
    assert_eq!(m.worker_respawns, 0, "budget 0 permits no respawn: {m:?}");
    assert!(m.worker_panics >= 1, "{m:?}");
    assert_eq!(m.live_workers, 0, "{m:?}");
}

/// Requests whose deadline lapses in the queue are shed at dequeue
/// with exact `deadline_expired` accounting — the executor never
/// spends a batch on them.
#[test]
fn queued_deadline_expiry_sheds_with_exact_accounting() {
    let policy = BatchPolicy { overload: OverloadPolicy::Block, ..BatchPolicy::default() };
    let coord = Coordinator::start_with(
        SyntheticExecutor::factory(SPEC, Duration::from_millis(200)),
        PoolConfig { workers: 1, policy, queue_depth: 16, ..PoolConfig::default() },
    )
    .expect("start pool");
    // Occupy the single worker with a deadline-free request...
    let occupant = {
        let client = coord.client();
        std::thread::spawn(move || client.infer(image(0)))
    };
    std::thread::sleep(Duration::from_millis(50));
    // ...then queue requests whose 5 ms deadline lapses long before
    // the 200 ms batch in front of them completes.
    let expired = 3usize;
    let mut handles = Vec::new();
    for i in 1..=expired {
        let client = coord.client();
        handles.push(std::thread::spawn(move || {
            client.infer_within(image(i), Some(Duration::from_millis(5)))
        }));
    }
    for h in handles {
        let err = h.join().expect("no hang").expect_err("queued past its deadline");
        assert!(is_deadline_error(&err), "typed deadline error, got: {err:#}");
    }
    assert!(occupant.join().expect("no hang").is_ok(), "occupant unaffected");
    let m = coord.metrics();
    assert_eq!(m.deadline_expired, expired as u64, "exact expiry accounting: {m:?}");
    assert_eq!(m.shed, 0, "deadline sheds are not overload sheds: {m:?}");
    assert_eq!(m.requests, 1, "only the occupant reached the executor: {m:?}");
    coord.shutdown();
}

/// Hand-encode a v1 infer frame — the pre-deadline wire layout an old
/// client still speaks.
fn encode_v1_infer(id: u64, model: &str, payload: &[f32]) -> Vec<u8> {
    let mut out = vec![0u8; 4];
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(1); // protocol version 1
    out.push(0); // kind: infer
    out.extend_from_slice(&id.to_le_bytes());
    out.push(1); // priority: normal
    out.push(model.len() as u8);
    out.push(7u8); // tenant "default"
    out.extend_from_slice(model.as_bytes());
    out.extend_from_slice(b"default");
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    for v in payload {
        out.extend_from_slice(&v.to_le_bytes());
    }
    let body_len = (out.len() - 4) as u32;
    out[0..4].copy_from_slice(&body_len.to_le_bytes());
    out
}

/// An old (v1) client gets correct logits back in a v1-stamped reply:
/// the server answers each peer at the version it spoke.
#[test]
fn v1_client_round_trips_against_v2_server() {
    let (_switch, registry, server) = serve_chaos(1, Duration::ZERO, 3);
    let addr = server.local_addr();
    let x = image(7);
    let bytes = encode_v1_infer(99, "toy", &x);
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.write_all(&bytes).expect("send v1 frame");
    stream.flush().expect("flush");
    let mut reader = FrameReader::new();
    let mut buf = [0u8; 4096];
    let frame = loop {
        let n = stream.read(&mut buf).expect("read reply");
        assert!(n > 0, "server closed before replying");
        reader.feed(&buf[..n]);
        if let Some(f) = reader.try_next().expect("well-formed reply") {
            break f;
        }
    };
    assert_eq!(reader.last_version(), 1, "reply must be stamped v1 for a v1 peer");
    let Frame::Response(r) = frame else { panic!("expected a response frame, got {frame:?}") };
    assert_eq!(r.id, 99);
    assert_eq!(r.status, Status::Ok, "{}", r.message);
    assert_eq!(r.logits, SyntheticExecutor::new(SPEC).reference_logits(&x));
    server.shutdown();
    registry.shutdown_all();
}

/// Byte-level connection chaos — malformed frames, a client dying
/// mid-frame, a one-byte-per-write slow sender — must never wedge the
/// server, and finished connection handles get reaped.
#[test]
fn connection_chaos_does_not_wedge_the_server_and_handles_are_reaped() {
    let (_switch, registry, server) = serve_chaos(1, Duration::ZERO, 3);
    let addr = server.local_addr();
    // Malformed frame: the server answers BadRequest and closes.
    let mut bad = TcpStream::connect(addr).expect("connect");
    bad.write_all(&malformed_frame()).expect("send garbage");
    bad.flush().expect("flush");
    let mut reply = Vec::new();
    bad.read_to_end(&mut reply).expect("server must close the bad connection");
    let mut reader = FrameReader::new();
    reader.feed(&reply);
    match reader.try_next().expect("reply decodes") {
        Some(Frame::Response(r)) => assert_eq!(r.status, Status::BadRequest),
        other => panic!("expected BadRequest response, got {other:?}"),
    }
    // A client dropping mid-frame leaves no wedged connection slot.
    let partial = encode_v1_infer(1, "toy", &image(1));
    let cut = partial.len() / 2;
    for _ in 0..4 {
        let stream = TcpStream::connect(addr).expect("connect");
        drop_after(stream, &partial, cut);
    }
    // A slow writer trickling a whole valid frame still gets served.
    let mut slow = TcpStream::connect(addr).expect("connect");
    slow_writer(&mut slow, &encode_v1_infer(2, "toy", &image(2)), Duration::from_millis(1))
        .expect("trickle a full frame");
    let mut reader = FrameReader::new();
    let mut buf = [0u8; 4096];
    let frame = loop {
        let n = slow.read(&mut buf).expect("read reply");
        assert!(n > 0, "server closed on the slow writer");
        reader.feed(&buf[..n]);
        if let Some(f) = reader.try_next().expect("well-formed reply") {
            break f;
        }
    };
    let Frame::Response(r) = frame else { panic!("expected response, got {frame:?}") };
    assert_eq!(r.status, Status::Ok, "{}", r.message);
    drop(slow);
    drop(bad);
    // Throughout the chaos, a normal client is served correctly.
    let mut client = NetClient::connect(addr).expect("connect");
    let x = image(3);
    assert_eq!(
        client.infer("toy", &x).expect("healthy request"),
        SyntheticExecutor::new(SPEC).reference_logits(&x)
    );
    // Closed connections get their handles reaped (accept-time reap +
    // 250 ms sweeper), so tracking stays bounded by live connections.
    let deadline = Instant::now() + Duration::from_secs(10);
    while server.connections_reaped() < 5 {
        assert!(
            Instant::now() < deadline,
            "reaper never collected finished handles: tracked={}, reaped={}",
            server.tracked_connections(),
            server.connections_reaped()
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(
        server.tracked_connections() <= 2,
        "only live connections may stay tracked, got {}",
        server.tracked_connections()
    );
    server.shutdown();
    registry.shutdown_all();
}
