//! PJRT runtime integration: loads the real AOT artifacts, trains, and
//! cross-checks the serving path. Requires `make artifacts`.

use scnn::data::{Dataset, Split, SynthDigits};
use scnn::runtime::{trainer::Knobs, Runtime, Trainer};

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/tnn_meta.txt").exists()
}

#[test]
fn meta_parses_and_matches_model() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::new("artifacts").unwrap();
    let meta = rt.load_meta("tnn").unwrap();
    assert_eq!(meta.name, "tnn");
    assert_eq!(meta.classes, 10);
    assert_eq!(meta.input, (1, 28, 28));
    // Parameter names match the Rust model config order.
    let cfg = scnn::nn::model::ModelCfg::tnn();
    let names: Vec<String> = meta.params.iter().map(|p| p.name.clone()).collect();
    assert_eq!(names, cfg.param_names());
}

#[test]
fn train_step_reduces_loss_via_pjrt() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::new("artifacts").unwrap();
    let data = SynthDigits::new();
    let mut tr = Trainer::new(&rt, "tnn").unwrap();
    let knobs = Knobs::quantized(8).with_res_bsl(None);
    let losses = tr.train(&data, 60, 0.1, knobs, |_, _| {}).unwrap();
    let head: f32 = losses[..10].iter().sum::<f32>() / 10.0;
    let tail: f32 = losses[losses.len() - 10..].iter().sum::<f32>() / 10.0;
    assert!(tail < head, "loss must decrease: {head} -> {tail}");
}

#[test]
fn serving_path_agrees_with_fake_quant() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::new("artifacts").unwrap();
    let data = SynthDigits::new();
    let mut tr = Trainer::new(&rt, "tnn").unwrap();
    let knobs = Knobs::quantized(2).with_res_bsl(None);
    tr.train_qat(&data, 120, 120, 0.1, knobs, |_, _| {}).unwrap();
    // The integer serving path (Pallas kernel) and the fake-quant path
    // must produce near-identical accuracies (identical rounding on
    // almost all inputs).
    let a = tr.accuracy(&data, 256, knobs, true).unwrap();
    let b = tr.accuracy(&data, 256, knobs, false).unwrap();
    assert!((a - b).abs() < 0.05, "serving {a} vs fake-quant {b}");
}

#[test]
fn frozen_params_run_in_sc_simulator() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::new("artifacts").unwrap();
    let data = SynthDigits::new();
    let mut tr = Trainer::new(&rt, "tnn").unwrap();
    let knobs = Knobs::quantized(2).with_res_bsl(None);
    tr.train_qat(&data, 350, 350, 0.1, knobs, |_, _| {}).unwrap();
    let params = tr.to_model_params();
    let prep = scnn::nn::sc_exec::Prepared::new(
        &scnn::nn::model::ModelCfg::tnn(),
        &params,
        scnn::nn::quant::QuantConfig {
            act_bsl: Some(2),
            weight_ternary: true,
            residual_bsl: None,
            pruning: scnn::nn::quant::Pruning::Off,
        },
    );
    let sc = scnn::nn::sc_exec::ScExecutor::new(prep.clone());
    let bin = scnn::nn::binary_exec::BinaryExecutor::new(prep);
    let (imgs, labels) = data.batch(Split::Test, 0, 48);
    let acc_sc = sc.accuracy(&imgs, &labels);
    let acc_bin = bin.accuracy(&imgs, &labels);
    assert_eq!(acc_sc, acc_bin, "executors must agree fault-free");
    // The trained network must beat chance decisively in the SC sim.
    assert!(acc_sc > 0.25, "SC-sim accuracy too low: {acc_sc}");
}

#[test]
fn set_params_roundtrip() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::new("artifacts").unwrap();
    let mut tr = Trainer::new(&rt, "tnn").unwrap();
    let blob = tr.params().to_vec();
    tr.set_params(blob.clone()).unwrap();
    assert_eq!(tr.params(), &blob[..]);
    // Wrong arity must fail.
    assert!(tr.set_params(vec![vec![0.0]]).is_err());
}
