//! Integration tests for the multi-worker inference pool, driven over
//! the deterministic synthetic backend (no artifacts / PJRT needed):
//! concurrent clients, logits equivalence across worker counts,
//! metrics consistency, load shedding, and graceful shutdown.

use std::time::Duration;

use scnn::coordinator::{
    is_shed_error, BatchPolicy, Coordinator, ExecutorSpec, OverloadPolicy, PoolConfig,
    SyntheticExecutor,
};

const SPEC: ExecutorSpec = ExecutorSpec { image_len: 12, batch: 4, classes: 5 };

/// A deterministic fake "image" for request index `i`.
fn image(i: usize) -> Vec<f32> {
    (0..SPEC.image_len)
        .map(|p| ((i * 31 + p * 7) % 17) as f32 * 0.125 - 1.0)
        .collect()
}

fn pool(workers: usize, latency: Duration) -> Coordinator {
    Coordinator::start_with(
        SyntheticExecutor::factory(SPEC, latency),
        PoolConfig { workers, ..PoolConfig::default() },
    )
    .expect("start pool")
}

#[test]
fn many_concurrent_clients_all_respond_with_correct_logits() {
    let coord = pool(4, Duration::ZERO);
    let clients = 16usize;
    let per_client = 32usize;
    let mut handles = Vec::new();
    for t in 0..clients {
        let client = coord.client();
        handles.push(std::thread::spawn(move || -> Vec<(usize, Vec<f32>)> {
            (0..per_client)
                .map(|i| {
                    let idx = t * per_client + i;
                    (idx, client.infer(image(idx)).expect("infer"))
                })
                .collect()
        }));
    }
    let reference = SyntheticExecutor::new(SPEC);
    let mut total = 0usize;
    for h in handles {
        for (idx, logits) in h.join().unwrap() {
            // Responses from a 4-worker pool are bit-identical to the
            // single-model ground truth regardless of which worker and
            // batch slot served the request.
            assert_eq!(logits, reference.reference_logits(&image(idx)), "request {idx}");
            total += 1;
        }
    }
    assert_eq!(total, clients * per_client);

    let m = coord.shutdown();
    assert_eq!(m.requests, (clients * per_client) as u64);
    assert_eq!(m.errors, 0);
    assert_eq!(m.shed, 0);
    assert_eq!(m.workers, 4);
    assert_eq!(m.per_worker.len(), 4);
    // Aggregate counters are exactly the sum of the per-worker rows.
    let req_sum: u64 = m.per_worker.iter().map(|w| w.requests).sum();
    let batch_sum: u64 = m.per_worker.iter().map(|w| w.batches).sum();
    assert_eq!(req_sum, m.requests);
    assert_eq!(batch_sum, m.batches);
    // Every request occupies one slot of a capacity-4 batch.
    assert!(m.batches >= m.requests / SPEC.batch as u64);
    assert!(m.occupancy > 0.0 && m.occupancy <= 1.0);
    assert!(m.p50 <= m.p99);
    assert!(m.inflight_peak >= 1);
}

#[test]
fn pool_logits_match_single_worker_pool() {
    let inputs: Vec<Vec<f32>> = (0..40).map(image).collect();
    let single = pool(1, Duration::ZERO);
    let expected: Vec<Vec<f32>> = inputs
        .iter()
        .map(|x| single.client().infer(x.clone()).unwrap())
        .collect();
    single.shutdown();

    let multi = pool(4, Duration::from_micros(200));
    let client = multi.client();
    let mut handles = Vec::new();
    for (i, x) in inputs.iter().enumerate() {
        let client = client.clone();
        let x = x.clone();
        handles.push(std::thread::spawn(move || (i, client.infer(x).unwrap())));
    }
    for h in handles {
        let (i, logits) = h.join().unwrap();
        assert_eq!(logits, expected[i], "input {i}");
    }
    multi.shutdown();
}

#[test]
fn load_shedding_rejects_and_counts_overflow() {
    let policy = BatchPolicy { overload: OverloadPolicy::Shed, ..BatchPolicy::default() };
    let coord = Coordinator::start_with(
        SyntheticExecutor::factory(SPEC, Duration::from_millis(25)),
        PoolConfig { workers: 1, policy, queue_depth: 2, ..PoolConfig::default() },
    )
    .expect("start pool");
    let clients = 12usize;
    let mut handles = Vec::new();
    for t in 0..clients {
        let client = coord.client();
        handles.push(std::thread::spawn(move || match client.infer(image(t)) {
            Ok(_) => (1usize, 0usize),
            Err(e) => {
                assert!(is_shed_error(&e), "unexpected error: {e:#}");
                (0, 1)
            }
        }));
    }
    let (mut ok, mut shed) = (0usize, 0usize);
    for h in handles {
        let (o, s) = h.join().unwrap();
        ok += o;
        shed += s;
    }
    assert_eq!(ok + shed, clients);
    let m = coord.shutdown();
    assert_eq!(m.requests, ok as u64);
    assert_eq!(m.shed, shed as u64, "snapshot shed must match client-observed rejections");
    // With a 25 ms batch, one worker and 2 queue slots, 12 instant
    // clients cannot all be admitted.
    assert!(shed > 0, "expected at least one shed request");
}

#[test]
fn graceful_shutdown_drains_queued_requests() {
    let coord = pool(2, Duration::from_millis(10));
    let mut handles = Vec::new();
    for t in 0..6usize {
        let client = coord.client();
        handles.push(std::thread::spawn(move || client.infer(image(t))));
    }
    // Let the submissions reach the shard queues, then stop the pool
    // while batches are still in flight. Drain invariant: an admitted
    // request is always served; a request that raced the stop flag may
    // only fail with "stopped" — never with a dropped response.
    std::thread::sleep(Duration::from_millis(50));
    let m = coord.shutdown();
    let mut served = 0u64;
    for h in handles {
        match h.join().unwrap() {
            Ok(logits) => {
                assert_eq!(logits.len(), SPEC.classes);
                served += 1;
            }
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(msg.contains("stopped"), "admitted request was dropped: {msg}");
            }
        }
    }
    assert_eq!(m.requests, served);
    assert!(served >= 1, "no request was served before shutdown");
}

#[test]
fn client_rejects_requests_after_shutdown() {
    let coord = pool(1, Duration::ZERO);
    let client = coord.client();
    assert!(client.infer(image(0)).is_ok());
    coord.shutdown();
    let err = client.infer(image(1)).unwrap_err();
    assert!(format!("{err:#}").contains("stopped"), "{err:#}");
}

#[test]
fn client_validates_image_length() {
    let coord = pool(1, Duration::ZERO);
    let client = coord.client();
    assert!(client.infer(vec![0.0; SPEC.image_len + 1]).is_err());
    assert_eq!(client.classes(), SPEC.classes);
    assert_eq!(client.workers(), 1);
    let class = client.classify(image(3)).unwrap();
    assert!(class < SPEC.classes);
    coord.shutdown();
}

#[test]
fn mismatched_worker_specs_are_rejected() {
    let factory: scnn::coordinator::ExecutorFactory = Box::new(|worker| {
        let spec = ExecutorSpec {
            image_len: 8,
            batch: if worker == 0 { 2 } else { 4 },
            classes: 3,
        };
        Ok(Box::new(SyntheticExecutor::new(spec)))
    });
    let err = Coordinator::start_with(factory, PoolConfig { workers: 2, ..PoolConfig::default() })
        .err()
        .expect("spec mismatch must fail startup");
    assert!(format!("{err:#}").contains("disagree"), "{err:#}");
}
