//! Engine/executor equivalence and native SC serving integration:
//!
//! * property test — batched [`ScEngine`] logits are bit-identical to
//!   the per-image [`ScExecutor`] on random images across BSLs and
//!   both model families (including the residual network);
//! * integration — `scnn serve --backend sc` semantics: a multi-worker
//!   pool over [`Backend::Sc`] returns, for every request, exactly the
//!   logits and class the single-threaded executor computes for the
//!   same fixed seed.

use std::sync::Arc;

use scnn::coordinator::{backend, Backend, Coordinator, ServeConfig};
use scnn::data::{Dataset, Split, SynthDigits};
use scnn::nn::model::{ModelCfg, ModelParams};
use scnn::nn::quant::{Pruning, QuantConfig};
use scnn::nn::sc_engine::ScEngine;
use scnn::nn::sc_exec::{Prepared, ScExecutor};
use scnn::nn::Tensor;
use scnn::util::prop::check_simple;
use scnn::util::Rng;

fn frozen(cfg: &ModelCfg, quant: QuantConfig, seed: u64) -> Arc<Prepared> {
    let mut rng = Rng::new(seed);
    let params = ModelParams::init(cfg, &mut rng);
    Arc::new(Prepared::new(cfg, &params, quant))
}

#[test]
fn prop_engine_logits_bit_identical_to_executor_tnn() {
    let cfg = ModelCfg::tnn();
    for bsl in [2usize, 4, 8] {
        let prep = frozen(
            &cfg,
            QuantConfig {
                act_bsl: Some(bsl),
                weight_ternary: true,
                residual_bsl: None,
                pruning: Pruning::Off,
            },
            100 + bsl as u64,
        );
        let exec = ScExecutor::new(prep.clone());
        let mut engine = ScEngine::new(prep);
        check_simple(
            0xEC0DE + bsl as u64,
            8,
            |rng| {
                // Random image, wide dynamic range so saturation paths
                // are exercised too.
                let scale = 0.25 + 2.0 * rng.f64() as f32;
                (0..784).map(|_| rng.normal() as f32 * scale).collect::<Vec<f32>>()
            },
            |pix| {
                let img = Tensor::from_vec(&[1, 28, 28], pix.clone());
                engine.forward(&img) == exec.forward(&img)
            },
        );
    }
}

#[test]
fn prop_engine_logits_bit_identical_to_executor_residual_scnet() {
    let cfg = ModelCfg::scnet(10);
    let prep = frozen(&cfg, QuantConfig::w2a2r16(), 7);
    let exec = ScExecutor::new(prep.clone());
    let mut engine = ScEngine::new(prep);
    check_simple(
        0x5C4E7,
        4,
        |rng| (0..3 * 32 * 32).map(|_| rng.normal() as f32 * 0.5).collect::<Vec<f32>>(),
        |pix| {
            let img = Tensor::from_vec(&[3, 32, 32], pix.clone());
            engine.forward(&img) == exec.forward(&img)
        },
    );
}

#[test]
fn sc_backend_pool_matches_single_threaded_executor() {
    // `scnn serve --backend sc --model tnn --workers 2` equivalent.
    let mut cfg = ServeConfig::new("artifacts", "tnn");
    cfg.workers = 2;
    cfg.batch = 4;
    cfg.seed = 123;
    // The single-threaded oracle: same (model, knobs, seed) freeze.
    let prep = backend::prepared_for(&cfg).expect("freeze model");
    let oracle = ScExecutor::new(prep);

    let coord = Coordinator::start_backend(Backend::Sc, cfg).expect("start sc pool");
    let client = coord.client();
    let data = SynthDigits::new();
    assert_eq!(client.classes(), 10);

    let mut handles = Vec::new();
    for t in 0..4usize {
        let client = client.clone();
        handles.push(std::thread::spawn(move || -> Vec<(usize, Vec<f32>, usize)> {
            let data = SynthDigits::new();
            (0..8usize)
                .map(|i| {
                    let idx = t * 1000 + i;
                    let (x, _) = data.sample(Split::Test, idx);
                    let logits = client.infer(x.data().to_vec()).expect("infer");
                    let class = client.classify(x.into_vec()).expect("classify");
                    (idx, logits, class)
                })
                .collect()
        }));
    }
    let mut total = 0usize;
    for h in handles {
        for (idx, logits, class) in h.join().unwrap() {
            let (x, _) = data.sample(Split::Test, idx);
            let expect: Vec<f32> =
                oracle.forward(&x).into_iter().map(|v| v as f32).collect();
            assert_eq!(logits, expect, "pool logits differ from ScExecutor for request {idx}");
            let expect_class = oracle.predict(std::slice::from_ref(&x))[0];
            assert_eq!(class, expect_class, "pool class differs for request {idx}");
            total += 1;
        }
    }
    assert_eq!(total, 32);
    let m = coord.shutdown();
    // Two requests per image (infer + classify).
    assert_eq!(m.requests, 64);
    assert_eq!(m.errors, 0);
}

#[test]
fn binary_backend_pool_serves_and_matches_sc_backend() {
    // Fault-free, the binary fixed-point datapath and the SC engine
    // compute the same quantized network — through the pool too.
    let mut cfg = ServeConfig::new("artifacts", "tnn");
    cfg.seed = 9;
    cfg.batch = 2;
    let data = SynthDigits::new();
    let mut answers = Vec::new();
    for backend in [Backend::Sc, Backend::Binary] {
        let coord = Coordinator::start_backend(backend, cfg.clone()).expect("start pool");
        let client = coord.client();
        let logits: Vec<Vec<f32>> = (0..6)
            .map(|i| client.infer(data.sample(Split::Test, i).0.into_vec()).expect("infer"))
            .collect();
        coord.shutdown();
        answers.push(logits);
    }
    assert_eq!(answers[0], answers[1], "sc and binary backends disagree fault-free");
}

#[test]
fn auto_backend_falls_back_to_synthetic_without_artifacts() {
    // Auto resolves to synthetic without artifacts and keeps serving.
    let mut cfg = ServeConfig::new("no/artifacts/here", "tnn");
    cfg.workers = 1;
    let resolved = Backend::Auto.resolve(&cfg.artifacts, &cfg.model);
    assert_eq!(resolved, Backend::Synthetic);
    let coord = Coordinator::start_backend(Backend::Auto, cfg).expect("start auto pool");
    let logits = coord.client().infer(vec![0.5; 784]).expect("infer");
    assert_eq!(logits.len(), 10);
    coord.shutdown();
}
