//! The GEMM core's contract: packed kernels are **exactly** the naive
//! triple loop, and the threaded engine is **exactly** the sequential
//! engine.
//!
//! * Property tests pit [`TernaryPanel`]/[`I8Panel`] against
//!   [`gemm_naive`] on random shapes, including ragged edges smaller
//!   than the channel block ([`BLOCK_CO`]) and the 4-wide microkernel.
//! * The runtime-dispatched SIMD arms are pitted against the pinned
//!   scalar table (`Dispatch::scalar()`) for every kernel entry point;
//!   CI re-runs the whole suite under `SCNN_NO_SIMD=1` so the
//!   forced-scalar arm is exercised as the dispatched one too.
//! * `ScEngine::forward_batch_into` must produce bit-identical logits
//!   at every thread count, for both model families (plain ternary
//!   `tnn` and the residual `scnet10`) — the order-safety claim of
//!   DESIGN.md §Perf "Ternary GEMM + threading".
//! * The serving pool honors `ServeConfig::threads` end to end: a
//!   threaded `sc` pool answers with the same logits as a
//!   single-threaded oracle over the same frozen model.
//! * Under injected faults the packed engine's count-domain folding is
//!   bit-identical to the scalar stream-materializing executor (same
//!   `FaultCfg`, same image tags, every thread count) — and because CI
//!   re-runs this suite under `SCNN_NO_SIMD=1`, the forced-scalar GEMM
//!   arm is exercised under faults too.
//! * The sparse (compressed-column) kernels are **exactly** the naive
//!   loop and the dense panels at every activation density — ragged
//!   shapes, zero/full-density extremes, word-crossing widths, forced
//!   scalar — and the engine's density-based sparse routing is
//!   bit-identical to the executor at every thread count (pruned
//!   freezes included).
//! * The datapath guard detects and recovers 100% of chaos-corrupted
//!   GEMM rows on the live engine — on the sparse route too — and a
//!   `--guard` pool serves clean logits while reporting integrity
//!   counters through its metrics.

use std::sync::Arc;

use scnn::coordinator::{backend, Backend, Coordinator, ServeConfig};
use scnn::fault::guard::{DatapathGuard, GuardCounters};
use scnn::nn::gemm::{gemm_naive, I8Panel, SparseCols, TernaryPanel, WeightPanels, BLOCK_CO};
use scnn::nn::model::{ModelCfg, ModelParams};
use scnn::nn::quant::{Pruning, QuantConfig};
use scnn::nn::sc_exec::{FaultCfg, Prepared, ScExecutor};
use scnn::nn::tensor::Tensor;
use scnn::nn::ScEngine;
use scnn::util::prop::check_simple;
use scnn::util::simd::Dispatch;
use scnn::util::Rng;

/// One random GEMM problem instance.
#[derive(Clone, Debug)]
struct Case {
    rows: usize,
    k: usize,
    n: usize,
    w: Vec<i8>,
    cols: Vec<i32>,
}

fn gen_case(rng: &mut Rng, ternary: bool) -> Case {
    // Bias the shape distribution toward the ragged edges: sizes
    // straddling the channel block and the 4-wide microkernel.
    let rows = rng.gen_range_i64(1, 2 * BLOCK_CO as i64 + 2) as usize;
    let k = rng.gen_range_i64(1, 160) as usize;
    let n = rng.gen_range_i64(1, 40) as usize;
    let w: Vec<i8> = (0..rows * k)
        .map(|_| {
            if ternary {
                rng.gen_range_i64(-1, 1) as i8
            } else {
                rng.gen_range_i64(-128, 127) as i8
            }
        })
        .collect();
    let cols: Vec<i32> = (0..n * k).map(|_| rng.gen_range_i64(-100, 101) as i32).collect();
    Case { rows, k, n, w, cols }
}

#[test]
fn ternary_panel_equals_naive_on_random_shapes() {
    check_simple(
        0xCE11,
        60,
        |rng| gen_case(rng, true),
        |c| {
            let mut expect = vec![0i64; c.rows * c.n];
            gemm_naive(&c.w, c.rows, c.k, &c.cols, c.n, &mut expect);
            let panel = TernaryPanel::pack(&c.w, c.rows, c.k);
            let mut got = vec![i64::MIN; c.rows * c.n];
            panel.gemm_into(&c.cols, c.n, &mut got);
            got == expect
        },
    );
}

#[test]
fn i8_panel_equals_naive_on_random_shapes() {
    check_simple(
        0xDEA1,
        60,
        |rng| gen_case(rng, false),
        |c| {
            let mut expect = vec![0i64; c.rows * c.n];
            gemm_naive(&c.w, c.rows, c.k, &c.cols, c.n, &mut expect);
            let panel = I8Panel::pack(&c.w, c.rows, c.k);
            let mut got = vec![i64::MIN; c.rows * c.n];
            panel.gemm_into(&c.cols, c.n, &mut got);
            got == expect
        },
    );
}

#[test]
fn both_pack_formats_agree_on_ternary_panels() {
    check_simple(
        0xACC0,
        40,
        |rng| gen_case(rng, true),
        |c| {
            let p = WeightPanels::pack(&c.w, c.rows, c.k);
            let mut a = vec![0i64; c.rows * c.n];
            let mut b = vec![0i64; c.rows * c.n];
            p.ternary.gemm_into(&c.cols, c.n, &mut a);
            p.dense.gemm_into(&c.cols, c.n, &mut b);
            a == b
        },
    );
}

#[test]
fn ragged_edges_smaller_than_the_blocks() {
    // Every dimension below its blocking factor at once.
    let mut rng = Rng::new(7);
    for (rows, k, n) in [(1usize, 1usize, 1usize), (3, 2, 3), (BLOCK_CO - 1, 5, 3)] {
        let w: Vec<i8> = (0..rows * k).map(|_| rng.gen_range_i64(-1, 1) as i8).collect();
        let cols: Vec<i32> = (0..n * k).map(|_| rng.gen_range_i64(-9, 10) as i32).collect();
        let mut expect = vec![0i64; rows * n];
        gemm_naive(&w, rows, k, &cols, n, &mut expect);
        let mut t = vec![0i64; rows * n];
        TernaryPanel::pack(&w, rows, k).gemm_into(&cols, n, &mut t);
        let mut d = vec![0i64; rows * n];
        I8Panel::pack(&w, rows, k).gemm_into(&cols, n, &mut d);
        assert_eq!(t, expect, "ternary rows={rows} k={k} n={n}");
        assert_eq!(d, expect, "dense rows={rows} k={k} n={n}");
    }
}

#[test]
fn edge_shapes_pinned_against_naive() {
    // The shapes the vector kernels must survive: k = 0 (empty
    // reduction — the kernels never run), single-pixel n = 1 (the
    // microkernel never engages), and k straddling the 8-wide SIMD
    // chunk so the remainder loop carries 0..=7 lanes.
    let mut rng = Rng::new(13);
    let shapes = [
        (3usize, 0usize, 4usize),
        (1, 0, 1),
        (5, 9, 1),
        (2, 7, 1),
        (4, 7, 5),
        (4, 8, 5),
        (4, 9, 5),
        (3, 15, 2),
        (3, 16, 2),
        (3, 17, 2),
        (BLOCK_CO + 1, 33, 4),
    ];
    for (rows, k, n) in shapes {
        for ternary in [true, false] {
            let w: Vec<i8> = (0..rows * k)
                .map(|_| {
                    if ternary {
                        rng.gen_range_i64(-1, 1) as i8
                    } else {
                        rng.gen_range_i64(-128, 127) as i8
                    }
                })
                .collect();
            let cols: Vec<i32> =
                (0..n * k).map(|_| rng.gen_range_i64(-100, 101) as i32).collect();
            let mut expect = vec![0i64; rows * n];
            gemm_naive(&w, rows, k, &cols, n, &mut expect);
            let mut got = vec![i64::MIN; rows * n];
            if ternary {
                TernaryPanel::pack(&w, rows, k).gemm_into(&cols, n, &mut got);
            } else {
                I8Panel::pack(&w, rows, k).gemm_into(&cols, n, &mut got);
            }
            assert_eq!(got, expect, "ternary={ternary} rows={rows} k={k} n={n}");
        }
    }
}

#[test]
fn all_zero_ternary_rows_have_empty_index_lists() {
    // Rows that pack to empty +1/−1 lists must still produce exact
    // zeros through the gathered-accumulate path.
    let (rows, k, n) = (4usize, 12usize, 3usize);
    let w = vec![0i8; rows * k];
    let cols: Vec<i32> = (0..n * k).map(|i| i as i32 - 7).collect();
    let panel = TernaryPanel::pack(&w, rows, k);
    assert_eq!(panel.nnz(), 0);
    let mut got = vec![i64::MIN; rows * n];
    panel.gemm_into(&cols, n, &mut got);
    assert_eq!(got, vec![0i64; rows * n]);
    assert_eq!(panel.row_dot(0, &cols[..k]), 0);
}

#[test]
fn dispatched_gemm_matches_forced_scalar() {
    // The acceptance bar of the SIMD step: the dispatched table (AVX2 /
    // NEON / scalar, whatever this machine selected) and the pinned
    // scalar table produce bit-identical results for every kernel entry
    // point, on random ragged shapes.
    let sc = Dispatch::scalar();
    check_simple(
        0x51D0,
        40,
        |rng| gen_case(rng, true),
        |c| {
            let panel = TernaryPanel::pack(&c.w, c.rows, c.k);
            let mut active = vec![0i64; c.rows * c.n];
            let mut scalar = vec![i64::MIN; c.rows * c.n];
            panel.gemm_into(&c.cols, c.n, &mut active);
            panel.gemm_into_with(sc, &c.cols, c.n, &mut scalar);
            assert_eq!(active, scalar, "ternary gemm");
            let x = &c.cols[..c.k];
            let x64: Vec<i64> = x.iter().map(|&v| v as i64).collect();
            for r in 0..c.rows {
                assert_eq!(panel.row_dot(r, x), panel.row_dot_with(sc, r, x), "row_dot r={r}");
                assert_eq!(
                    panel.row_dot_i64(r, &x64),
                    panel.row_dot_i64_with(sc, r, &x64),
                    "row_dot_i64 r={r}"
                );
            }
            true
        },
    );
    check_simple(
        0x51D1,
        40,
        |rng| gen_case(rng, false),
        |c| {
            let panel = I8Panel::pack(&c.w, c.rows, c.k);
            let mut active = vec![0i64; c.rows * c.n];
            let mut scalar = vec![i64::MIN; c.rows * c.n];
            panel.gemm_into(&c.cols, c.n, &mut active);
            panel.gemm_into_with(sc, &c.cols, c.n, &mut scalar);
            assert_eq!(active, scalar, "dense gemm");
            let x = &c.cols[..c.k];
            for r in 0..c.rows {
                assert_eq!(panel.row_dot(r, x), panel.row_dot_with(sc, r, x), "row_dot r={r}");
            }
            true
        },
    );
}

/// Zero out entries of `cols` with probability `zero_p`.
fn sparsify(rng: &mut Rng, cols: &mut [i32], zero_p: f64) {
    for v in cols.iter_mut() {
        if rng.gen_bool(zero_p) {
            *v = 0;
        }
    }
}

#[test]
fn sparse_gemm_equals_naive_and_dense_on_random_shapes() {
    // Tentpole acceptance: the compressed-column kernels are exactly
    // the naive loop (and therefore the dense panels) on random ragged
    // shapes at every density, through both the dispatched and the
    // pinned-scalar tables.
    let sc = Dispatch::scalar();
    check_simple(
        0x5BA5,
        60,
        |rng| {
            let mut c = gen_case(rng, true);
            let zero_p = [0.0, 0.25, 0.5, 0.75, 0.9, 1.0][rng.gen_index(6)];
            sparsify(rng, &mut c.cols, zero_p);
            c
        },
        |c| {
            let mut expect = vec![0i64; c.rows * c.n];
            gemm_naive(&c.w, c.rows, c.k, &c.cols, c.n, &mut expect);
            let sp = SparseCols::compress(&c.cols, c.n, c.k);
            let panel = TernaryPanel::pack(&c.w, c.rows, c.k);
            let mut got = vec![i64::MIN; c.rows * c.n];
            panel.gemm_sparse_into(&sp, &mut got);
            assert_eq!(got, expect, "ternary sparse (dispatched)");
            let mut got_s = vec![i64::MIN; c.rows * c.n];
            panel.gemm_sparse_into_with(sc, &sp, &mut got_s);
            assert_eq!(got_s, expect, "ternary sparse (forced scalar)");
            true
        },
    );
    check_simple(
        0x5BA6,
        60,
        |rng| {
            let mut c = gen_case(rng, false);
            let zero_p = [0.0, 0.25, 0.5, 0.75, 0.9, 1.0][rng.gen_index(6)];
            sparsify(rng, &mut c.cols, zero_p);
            c
        },
        |c| {
            let mut expect = vec![0i64; c.rows * c.n];
            gemm_naive(&c.w, c.rows, c.k, &c.cols, c.n, &mut expect);
            let sp = SparseCols::compress(&c.cols, c.n, c.k);
            let panel = I8Panel::pack(&c.w, c.rows, c.k);
            let mut got = vec![i64::MIN; c.rows * c.n];
            panel.gemm_sparse_into(&sp, &mut got);
            assert_eq!(got, expect, "dense-panel sparse (dispatched)");
            let mut got_s = vec![i64::MIN; c.rows * c.n];
            panel.gemm_sparse_into_with(sc, &sp, &mut got_s);
            assert_eq!(got_s, expect, "dense-panel sparse (forced scalar)");
            true
        },
    );
}

#[test]
fn sparse_gemm_extremes_and_word_crossing_widths() {
    // Pinned shapes: empty reduction (k = 0), single pixels, k
    // straddling the 8-wide gather chunk, rows straddling the channel
    // block — each at zero, half, and full density.
    let sc = Dispatch::scalar();
    let mut rng = Rng::new(41);
    let shapes = [
        (3usize, 0usize, 4usize),
        (1, 1, 1),
        (4, 7, 5),
        (4, 8, 5),
        (4, 9, 5),
        (3, 15, 2),
        (3, 16, 2),
        (3, 17, 2),
        (BLOCK_CO + 1, 33, 4),
        (13, 37, 19),
    ];
    for (rows, k, n) in shapes {
        for zero_p in [0.0, 0.5, 1.0] {
            for ternary in [true, false] {
                let w: Vec<i8> = (0..rows * k)
                    .map(|_| {
                        if ternary {
                            rng.gen_range_i64(-1, 1) as i8
                        } else {
                            rng.gen_range_i64(-128, 127) as i8
                        }
                    })
                    .collect();
                let mut cols: Vec<i32> =
                    (0..n * k).map(|_| rng.gen_range_i64(-100, 101) as i32).collect();
                sparsify(&mut rng, &mut cols, zero_p);
                let mut expect = vec![0i64; rows * n];
                gemm_naive(&w, rows, k, &cols, n, &mut expect);
                let sp = SparseCols::compress(&cols, n, k);
                if zero_p == 1.0 {
                    assert_eq!(sp.nnz(), 0, "full-zero panel must compress to empty");
                }
                let mut got = vec![i64::MIN; rows * n];
                let mut got_s = vec![i64::MIN; rows * n];
                if ternary {
                    let p = TernaryPanel::pack(&w, rows, k);
                    p.gemm_sparse_into(&sp, &mut got);
                    p.gemm_sparse_into_with(sc, &sp, &mut got_s);
                } else {
                    let p = I8Panel::pack(&w, rows, k);
                    p.gemm_sparse_into(&sp, &mut got);
                    p.gemm_sparse_into_with(sc, &sp, &mut got_s);
                }
                assert_eq!(got, expect, "ternary={ternary} rows={rows} k={k} n={n} p={zero_p}");
                assert_eq!(got_s, expect, "scalar ternary={ternary} k={k} n={n} p={zero_p}");
            }
        }
    }
}

fn prep_family(family: &str, seed: u64) -> (Arc<Prepared>, usize) {
    let (cfg, quant) = match family {
        "tnn" => (
            ModelCfg::tnn(),
            QuantConfig {
                act_bsl: Some(2),
                weight_ternary: true,
                residual_bsl: None,
                pruning: Pruning::Off,
            },
        ),
        "scnet10" => (ModelCfg::scnet(10), QuantConfig::w2a2r16()),
        other => panic!("unknown family {other}"),
    };
    let mut rng = Rng::new(seed);
    let params = ModelParams::init(&cfg, &mut rng);
    let (c, h, w) = cfg.input;
    (Arc::new(Prepared::new(&cfg, &params, quant)), c * h * w)
}

#[test]
fn threaded_batch_bit_identity_both_families() {
    // The acceptance bar of the threading knob: for both model families
    // and every thread count (1, fewer than batch, equal, more), the
    // batched logits are bit-identical to the sequential path.
    for family in ["tnn", "scnet10"] {
        let (prep, il) = prep_family(family, 11);
        let mut seq = ScEngine::new(prep.clone());
        let cl = seq.classes();
        let batch = 6usize;
        let mut rng = Rng::new(29);
        let x: Vec<f32> = (0..batch * il).map(|_| rng.normal() as f32 * 0.5).collect();
        let mut expect = vec![0i64; batch * cl];
        seq.forward_batch_into(&x, &mut expect);
        for threads in [1usize, 2, 3, 6, 9] {
            let mut eng = ScEngine::with_threads(prep.clone(), threads);
            let mut got = vec![0i64; batch * cl];
            eng.forward_batch_into(&x, &mut got);
            assert_eq!(got, expect, "{family} threads={threads}");
            // Scratch arenas are reused across calls: a second pass
            // must reproduce the same bits.
            let mut again = vec![0i64; batch * cl];
            eng.forward_batch_into(&x, &mut again);
            assert_eq!(again, expect, "{family} threads={threads} (second pass)");
        }
    }
}

#[test]
fn sc_pool_honors_the_threads_knob() {
    // End-to-end: a 2-worker x 2-thread sc pool serves the same logits
    // as a single-threaded engine over the same frozen model.
    let mut cfg = ServeConfig::new("artifacts", "tnn");
    cfg.workers = 2;
    cfg.threads = 2;
    cfg.batch = 4;
    cfg.queue_depth = 32;
    cfg.seed = 77;
    // Same freeze the backend performs: deterministic in the seed.
    let mut oracle = ScEngine::new(backend::prepared_for(&cfg).expect("freeze model"));
    let il = oracle.image_len();
    let coord = Coordinator::start_backend(Backend::Sc, cfg).expect("start sc pool");
    let client = coord.client();
    let mut rng = Rng::new(5);
    for i in 0..12 {
        let x: Vec<f32> = (0..il).map(|_| rng.normal() as f32).collect();
        let got = client.infer(x.clone()).expect("infer");
        let mut want = vec![0i64; oracle.classes()];
        oracle.forward_into(&x, &mut want);
        let want_f: Vec<f32> = want.iter().map(|&v| v as f32).collect();
        assert_eq!(got, want_f, "request {i}");
    }
    coord.shutdown();
}

#[test]
fn faulted_engine_matches_scalar_fault_network() {
    // Tentpole acceptance: under injected faults the packed engine is
    // bit-identical to the scalar stream-materializing executor — same
    // `FaultCfg`, images tagged by index — for both model families
    // (plain ternary and residual) at word-crossing stream widths, and
    // at every thread count on both the batch and the tagged
    // single-image paths.
    let fc = FaultCfg { ber: 0.05, seed: 99 };
    for family in ["tnn", "scnet10"] {
        let (prep, il) = prep_family(family, 23);
        let exec = ScExecutor::with_faults(prep.clone(), fc);
        let (c, h, w) = prep.cfg.input;
        let mut rng = Rng::new(31);
        let batch = 4usize;
        let x: Vec<f32> = (0..batch * il).map(|_| rng.normal() as f32 * 0.5).collect();
        let mut expect = Vec::new();
        for b in 0..batch {
            let img = Tensor::from_vec(&[c, h, w], x[b * il..(b + 1) * il].to_vec());
            expect.extend(exec.forward_with_tag(&img, b as u64));
        }
        for threads in [1usize, 2, 3, 6] {
            let mut eng = ScEngine::with_threads(prep.clone(), threads);
            eng.set_fault(Some(fc));
            let cl = eng.classes();
            let mut got = vec![0i64; batch * cl];
            eng.forward_batch_into(&x, &mut got);
            assert_eq!(got, expect, "{family} threads={threads} (batch path)");
            let mut one = vec![0i64; cl];
            for b in 0..batch {
                eng.forward_into_tagged(&x[b * il..(b + 1) * il], b as u64, &mut one);
                assert_eq!(
                    one[..],
                    expect[b * cl..(b + 1) * cl],
                    "{family} threads={threads} image {b} (tagged path)"
                );
            }
        }
    }
}

#[test]
fn chaos_guard_detects_and_recovers_on_the_live_engine() {
    // Guard acceptance: with the chaos knob corrupting *every* GEMM
    // row block before the check, the served logits still equal the
    // unguarded clean engine's — 100% detection, 100% recovery — and
    // the faulted path is unaffected (the guard protects the GEMM
    // stage; injected circuit faults apply after it).
    let (prep, il) = prep_family("scnet10", 17);
    let mut rng = Rng::new(3);
    let x: Vec<f32> = (0..il).map(|_| rng.normal() as f32).collect();
    let mut clean = ScEngine::new(prep.clone());
    let cl = clean.classes();
    let mut want = vec![0i64; cl];
    clean.forward_into(&x, &mut want);
    let counters = Arc::new(GuardCounters::default());
    let mut eng = ScEngine::with_threads(prep.clone(), 2);
    eng.set_guard(Some(Arc::new(DatapathGuard::with_chaos(counters.clone(), 1))));
    let mut got = vec![0i64; cl];
    eng.forward_into(&x, &mut got);
    assert_eq!(got, want, "every chaos-corrupted row must be healed");
    assert!(counters.detected() > 0, "chaos must have corrupted rows");
    assert_eq!(counters.detected(), counters.recovered(), "recovery must be 100%");

    // Production guard on clean hardware: nothing to detect, logits
    // untouched; and guard + fault injection compose (guard first,
    // stage faults after).
    let fc = FaultCfg { ber: 0.02, seed: 5 };
    let mut faulted = ScEngine::new(prep.clone());
    faulted.set_fault(Some(fc));
    let mut want_f = vec![0i64; cl];
    faulted.forward_into(&x, &mut want_f);
    let quiet = Arc::new(GuardCounters::default());
    let mut guarded = ScEngine::new(prep);
    guarded.set_guard(Some(Arc::new(DatapathGuard::new(quiet.clone()))));
    guarded.set_fault(Some(fc));
    let mut got_f = vec![0i64; cl];
    guarded.forward_into(&x, &mut got_f);
    assert_eq!(got_f, want_f, "a clean guard must not change faulted logits");
    assert_eq!(quiet.detected(), 0);
    assert_eq!(quiet.recovered(), 0);
}

#[test]
fn guarded_sc_pool_serves_clean_logits_and_reports_metrics() {
    // `ServeConfig::guard` end to end: a guarded threaded pool answers
    // with the oracle's logits, and the integrity counter families show
    // up (at zero — the hardware is healthy) in the metrics snapshot.
    let mut cfg = ServeConfig::new("artifacts", "tnn");
    cfg.workers = 2;
    cfg.threads = 2;
    cfg.batch = 4;
    cfg.queue_depth = 32;
    cfg.seed = 77;
    cfg.guard = true;
    let mut oracle = ScEngine::new(backend::prepared_for(&cfg).expect("freeze model"));
    let il = oracle.image_len();
    let coord = Coordinator::start_backend(Backend::Sc, cfg).expect("start guarded sc pool");
    let client = coord.client();
    let mut rng = Rng::new(9);
    for i in 0..8 {
        let x: Vec<f32> = (0..il).map(|_| rng.normal() as f32).collect();
        let got = client.infer(x.clone()).expect("infer");
        let mut want = vec![0i64; oracle.classes()];
        oracle.forward_into(&x, &mut want);
        let want_f: Vec<f32> = want.iter().map(|&v| v as f32).collect();
        assert_eq!(got, want_f, "request {i}");
    }
    let m = coord.shutdown();
    assert_eq!(m.integrity_detected, 0, "healthy hardware must trip no checks");
    assert_eq!(m.integrity_recovered, 0);
}

/// A mostly-zero image batch: every `stride`-th pixel carries signal,
/// the rest are exact zeros, so the measured activation density drives
/// the engine onto the sparse route.
fn sparse_batch(rng: &mut Rng, batch: usize, il: usize, stride: usize) -> Vec<f32> {
    let mut x = vec![0f32; batch * il];
    for v in x.iter_mut().step_by(stride) {
        *v = rng.normal() as f32 * 2.0;
    }
    x
}

#[test]
fn sparse_routing_bit_identical_to_executor_at_every_thread_count() {
    // Tentpole acceptance: on images sparse enough to engage the
    // compressed-panel route, the engine's logits equal the per-image
    // executor's at every thread count, and repeat passes over the
    // reused scratch arenas (including the recycled `SparseCols`
    // buffers) reproduce the same bits.
    for family in ["tnn", "scnet10"] {
        let (prep, il) = prep_family(family, 53);
        let exec = ScExecutor::new(prep.clone());
        let (c, h, w) = prep.cfg.input;
        let batch = 4usize;
        let mut rng = Rng::new(61);
        let x = sparse_batch(&mut rng, batch, il, 17);
        let mut expect = Vec::new();
        for b in 0..batch {
            let img = Tensor::from_vec(&[c, h, w], x[b * il..(b + 1) * il].to_vec());
            expect.extend(exec.forward(&img));
        }
        let cl = expect.len() / batch;
        for threads in [1usize, 2, 3, 6] {
            let mut eng = ScEngine::with_threads(prep.clone(), threads);
            let mut got = vec![0i64; batch * cl];
            eng.forward_batch_into(&x, &mut got);
            assert_eq!(got, expect, "{family} threads={threads} (sparse route)");
            let mut again = vec![0i64; batch * cl];
            eng.forward_batch_into(&x, &mut again);
            assert_eq!(again, expect, "{family} threads={threads} (second pass)");
        }
    }
}

#[test]
fn pruned_engine_matches_executor_at_every_thread_count() {
    // Structured weight pruning happens at freeze time, so engine and
    // executor share the identical pruned panels — logits must stay
    // bit-identical across thread counts for both pruning schemes.
    let cfg = ModelCfg::tnn();
    let mut rng = Rng::new(67);
    let params = ModelParams::init(&cfg, &mut rng);
    for pruning in [Pruning::Nm { n: 2, m: 4 }, Pruning::Block { size: 4 }] {
        let prep = Arc::new(Prepared::new(
            &cfg,
            &params,
            QuantConfig {
                act_bsl: Some(2),
                weight_ternary: true,
                residual_bsl: None,
                pruning,
            },
        ));
        let exec = ScExecutor::new(prep.clone());
        let (c, h, w) = prep.cfg.input;
        let il = c * h * w;
        let batch = 3usize;
        let x: Vec<f32> = (0..batch * il).map(|_| rng.normal() as f32 * 0.5).collect();
        let mut expect = Vec::new();
        for b in 0..batch {
            let img = Tensor::from_vec(&[c, h, w], x[b * il..(b + 1) * il].to_vec());
            expect.extend(exec.forward(&img));
        }
        let cl = expect.len() / batch;
        for threads in [1usize, 2, 5] {
            let mut eng = ScEngine::with_threads(prep.clone(), threads);
            let mut got = vec![0i64; batch * cl];
            eng.forward_batch_into(&x, &mut got);
            assert_eq!(got, expect, "{pruning:?} threads={threads}");
        }
    }
}

#[test]
fn chaos_guard_recovers_on_the_sparse_route() {
    // Satellite acceptance: the guard's count-domain checksums are
    // computed from the dense im2col panel, which the sparse route
    // still fills — so with chaos corrupting every row block on a
    // sparse image, detection and recovery stay 100% and the served
    // logits equal the clean engine's.
    let (prep, il) = prep_family("tnn", 71);
    let mut rng = Rng::new(73);
    let x = sparse_batch(&mut rng, 1, il, 19);
    let mut clean = ScEngine::new(prep.clone());
    let cl = clean.classes();
    let mut want = vec![0i64; cl];
    clean.forward_into(&x, &mut want);
    let counters = Arc::new(GuardCounters::default());
    let mut eng = ScEngine::with_threads(prep, 2);
    eng.set_guard(Some(Arc::new(DatapathGuard::with_chaos(counters.clone(), 1))));
    let mut got = vec![0i64; cl];
    eng.forward_into(&x, &mut got);
    assert_eq!(got, want, "sparse-route chaos corruption must be healed");
    assert!(counters.detected() > 0, "chaos must have corrupted rows");
    assert_eq!(counters.detected(), counters.recovered(), "recovery must be 100%");
}
